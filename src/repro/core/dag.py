"""Logical DAG specification for stream-processing workloads (Trevor §2.1).

A :class:`DagSpec` is the *logical* topology the programmer writes: user-defined
nodes stitched together by grouping operators (fields / shuffle / all).  A
:class:`Configuration` is the *physical* deployment of that DAG: per-node
parallelism, container dimensions, container count and the packing of node
instances onto containers (Trevor table 1).

Everything downstream (the simulator, the flow solver, the allocator) consumes
these two data structures.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Mapping, Sequence


class Grouping(enum.Enum):
    """Heron's three default data-grouping operators (Trevor §2.1)."""

    FIELDS = "fields"    # hash(key) -> one downstream instance per key
    SHUFFLE = "shuffle"  # random downstream instance (load-balanced)
    ALL = "all"          # broadcast to every downstream instance


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """A user-defined DAG node (spout or bolt in Heron terms).

    ``cpu_cost_per_ktuple`` is the *ground-truth* CPU-seconds consumed per
    kilotuple of input — the simulator uses it; Trevor never reads it (it must
    learn it from metrics).  ``gamma`` is the ground-truth output:input rate
    ratio.  ``mem_mb_per_ktps``/``mem_mb_base`` define the ground-truth memory
    footprint as a function of the tuple rate mapped to an instance.
    ``io_fraction`` is the fraction of busy time the node spends blocked on
    I/O rather than on-CPU (Kafka ingestion nodes etc., Trevor §4).
    """

    name: str
    cpu_cost_per_ktuple: float
    gamma: float = 1.0
    mem_mb_base: float = 128.0
    mem_mb_per_ktps: float = 0.0
    io_fraction: float = 0.0
    tuple_bytes: float = 100.0  # size of this node's *output* tuples
    is_source: bool = False
    # Optional real computation for the executor path (operates on a tuple batch).
    fn: Callable | None = dataclasses.field(default=None, compare=False)

    def __post_init__(self) -> None:
        if self.cpu_cost_per_ktuple < 0:
            raise ValueError(f"node {self.name}: negative cpu cost")
        if self.gamma < 0:
            raise ValueError(f"node {self.name}: negative gamma")
        if not 0.0 <= self.io_fraction < 1.0:
            raise ValueError(f"node {self.name}: io_fraction must be in [0,1)")


@dataclasses.dataclass(frozen=True)
class EdgeSpec:
    """A directed edge ``src -> dst`` with a grouping operator."""

    src: str
    dst: str
    grouping: Grouping = Grouping.SHUFFLE


@dataclasses.dataclass(frozen=True)
class DagSpec:
    """A logical streaming DAG."""

    name: str
    nodes: tuple[NodeSpec, ...]
    edges: tuple[EdgeSpec, ...]

    def __post_init__(self) -> None:
        names = [n.name for n in self.nodes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate node names in {self.name}")
        nameset = set(names)
        for e in self.edges:
            if e.src not in nameset or e.dst not in nameset:
                raise ValueError(f"edge {e.src}->{e.dst} references unknown node")
            if e.src == e.dst:
                raise ValueError("self-loops are not allowed in a DAG")
        # acyclicity check via topological sort
        self.topological_order()

    # -- queries ----------------------------------------------------------
    def node(self, name: str) -> NodeSpec:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    @property
    def node_names(self) -> tuple[str, ...]:
        return tuple(n.name for n in self.nodes)

    def sources(self) -> tuple[NodeSpec, ...]:
        indeg = {n.name: 0 for n in self.nodes}
        for e in self.edges:
            indeg[e.dst] += 1
        return tuple(n for n in self.nodes if indeg[n.name] == 0)

    def out_edges(self, name: str) -> tuple[EdgeSpec, ...]:
        return tuple(e for e in self.edges if e.src == name)

    def in_edges(self, name: str) -> tuple[EdgeSpec, ...]:
        return tuple(e for e in self.edges if e.dst == name)

    def topological_order(self) -> tuple[str, ...]:
        indeg = {n.name: 0 for n in self.nodes}
        for e in self.edges:
            indeg[e.dst] += 1
        ready = [n for n, d in sorted(indeg.items()) if d == 0]
        order: list[str] = []
        indeg = dict(indeg)
        while ready:
            u = ready.pop(0)
            order.append(u)
            for e in self.out_edges(u):
                indeg[e.dst] -= 1
                if indeg[e.dst] == 0:
                    ready.append(e.dst)
        if len(order) != len(self.nodes):
            raise ValueError(f"DAG {self.name} has a cycle")
        return tuple(order)

    def gamma_rates(self, source_rate: float = 1.0) -> dict[str, float]:
        """Propagate input rates through the DAG using ground-truth gammas.

        Returns the steady-state *input* rate of every node when every source
        emits ``source_rate`` (after its own gamma).  Used by tests and by the
        allocator (with learned gammas substituted via ``gamma_overrides``).
        """
        return propagate_rates(
            self, source_rate, {n.name: n.gamma for n in self.nodes}
        )


def propagate_rates(
    dag: DagSpec, source_rate: float, gammas: Mapping[str, float]
) -> dict[str, float]:
    """Propagate per-node *input* rates through ``dag`` given gamma factors.

    A source node's "input" rate is defined as ``source_rate`` (the external
    offered load); its output is ``gamma * source_rate``.  Multiple in-edges
    sum.  ALL-grouping broadcast multiplies by downstream parallelism only at
    the *physical* layer, so it does not appear here (logical rates).
    """
    inrate: dict[str, float] = {n.name: 0.0 for n in dag.nodes}
    for s in dag.sources():
        inrate[s.name] = source_rate
    for u in dag.topological_order():
        out = inrate[u] * gammas[u]
        outs = dag.out_edges(u)
        if not outs:
            continue
        for e in outs:
            # each out-edge carries the full output stream (Heron semantics:
            # every downstream bolt subscribed to the stream sees all tuples)
            inrate[e.dst] += out
    return inrate


# ---------------------------------------------------------------------------
# Physical configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ContainerDim:
    """Container dimensions — continuous axes (Trevor §2.1)."""

    cpus: float = 3.0
    mem_mb: float = 4096.0
    link_mbps: float = 10_000.0  # NIC capacity per container

    def __post_init__(self) -> None:
        if self.cpus <= 0 or self.mem_mb <= 0 or self.link_mbps <= 0:
            raise ValueError("container dimensions must be positive")

    def scaled(self, alpha: float) -> "ContainerDim":
        return ContainerDim(self.cpus * alpha, self.mem_mb * alpha, self.link_mbps)


@dataclasses.dataclass(frozen=True)
class Configuration:
    """A physical deployment plan for a DagSpec.

    ``packing[c]`` lists the node-name of every instance placed in container
    ``c``; a node may appear several times in one container (multiple
    instances).  Parallelism of node ``v`` is the total count of ``v`` across
    all containers.  Every container implicitly hosts one stream manager.
    """

    dag: DagSpec
    packing: tuple[tuple[str, ...], ...]
    dims: tuple[ContainerDim, ...] = ()

    def __post_init__(self) -> None:
        if not self.packing:
            raise ValueError("configuration must have at least one container")
        if self.dims and len(self.dims) != len(self.packing):
            raise ValueError("dims must match container count (or be empty)")
        if not self.dims:
            object.__setattr__(
                self, "dims", tuple(ContainerDim() for _ in self.packing)
            )
        known = set(self.dag.node_names)
        for c in self.packing:
            for inst in c:
                if inst not in known:
                    raise ValueError(f"unknown node {inst!r} in packing")

    # -- queries ----------------------------------------------------------
    @property
    def n_containers(self) -> int:
        return len(self.packing)

    def parallelism(self, name: str) -> int:
        return sum(c.count(name) for c in self.packing)

    def parallelism_map(self) -> dict[str, int]:
        return {n: self.parallelism(n) for n in self.dag.node_names}

    def instances(self) -> list[tuple[str, int, int]]:
        """All physical instances as (node_name, container_idx, slot_idx)."""
        out = []
        for ci, c in enumerate(self.packing):
            for si, inst in enumerate(c):
                out.append((inst, ci, si))
        return out

    def total_cpus(self) -> float:
        return float(sum(d.cpus for d in self.dims))

    def total_mem_mb(self) -> float:
        return float(sum(d.mem_mb for d in self.dims))

    def describe(self) -> str:
        packs = []
        for c in self.packing:
            counts: dict[str, int] = {}
            for i in c:
                counts[i] = counts.get(i, 0) + 1
            packs.append(
                "(" + ",".join(f"{k}x{v}" if v > 1 else k for k, v in counts.items()) + ")"
            )
        return f"{self.dag.name}[{self.n_containers}c: {' '.join(packs)}]"


def round_robin_configuration(
    dag: DagSpec,
    parallelism: Mapping[str, int],
    n_containers: int,
    dim: ContainerDim = ContainerDim(),
) -> Configuration:
    """The baseline packing used throughout the paper's sensitivity study:
    instances of each node are dealt round-robin onto ``n_containers``."""
    packs: list[list[str]] = [[] for _ in range(n_containers)]
    i = 0
    for name in dag.node_names:
        for _ in range(int(parallelism[name])):
            packs[i % n_containers].append(name)
            i += 1
    return Configuration(
        dag=dag,
        packing=tuple(tuple(p) for p in packs),
        dims=tuple(dim for _ in range(n_containers)),
    )


def single_container_configuration(
    dag: DagSpec,
    parallelism: Mapping[str, int],
    cpus: float = 1e9,
    mem_mb: float = 1e12,
) -> Configuration:
    """The paper's "optimal line" reference (fig. 14): all instances in one
    container with unbounded resources and a free stream manager."""
    pack = []
    for name in dag.node_names:
        pack.extend([name] * int(parallelism[name]))
    return Configuration(
        dag=dag,
        packing=(tuple(pack),),
        dims=(ContainerDim(cpus=cpus, mem_mb=mem_mb, link_mbps=1e12),),
    )
