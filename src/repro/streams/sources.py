"""Load-trace generators (Trevor §2.3).

Streaming services see diurnal/weekly variation (LinkedIn 12.7→18 M ev/s,
Netflix 4.6→8 M ev/s), plus transient spikes up to 25× average lasting
minutes (World-Cup-goal effects).  These generators produce ktps traces used
by the autoscaler benchmarks and examples.
"""
from __future__ import annotations

import numpy as np


def diurnal(
    n: int,
    base_ktps: float = 400.0,
    peak_ratio: float = 3.0,
    period: int = 288,
    seed: int = 0,
    jitter: float = 0.05,
) -> np.ndarray:
    """Sinusoidal day curve: peak/average ≈ the paper's 3-5× daily pattern."""
    rng = np.random.default_rng(seed)
    t = np.arange(n)
    day = 0.5 * (1 + np.sin(2 * np.pi * t / period - np.pi / 2))
    trace = base_ktps * (1.0 + (peak_ratio - 1.0) * day)
    return trace * (1.0 + jitter * rng.standard_normal(n))


def spike(
    n: int,
    base_ktps: float = 400.0,
    spike_ratio: float = 20.0,
    spike_start: int | None = None,
    spike_len: int = 6,
    seed: int = 0,
) -> np.ndarray:
    """A World-Cup-style transient: up to 20-25× the average for minutes."""
    rng = np.random.default_rng(seed)
    trace = base_ktps * (1.0 + 0.05 * rng.standard_normal(n))
    s = spike_start if spike_start is not None else n // 2
    ramp = np.linspace(1.0, spike_ratio, max(spike_len // 2, 1))
    down = np.linspace(spike_ratio, 1.0, max(spike_len - spike_len // 2, 1))
    prof = np.concatenate([ramp, down])
    e = min(s + prof.shape[0], n)
    trace[s:e] *= prof[: e - s]
    return trace


def weekly(
    n: int,
    base_ktps: float = 400.0,
    day_period: int = 288,
    seed: int = 0,
) -> np.ndarray:
    """Seven-day pattern with weekend dips (mobile-network style 1.6k→83k)."""
    rng = np.random.default_rng(seed)
    t = np.arange(n)
    day = 0.5 * (1 + np.sin(2 * np.pi * t / day_period - np.pi / 2))
    dow = (t // day_period) % 7
    weekend = np.where(dow >= 5, 0.6, 1.0)
    return base_ktps * (0.5 + 2.5 * day) * weekend * (1 + 0.04 * rng.standard_normal(n))
