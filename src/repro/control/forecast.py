"""Load forecasting: the **forecast** phase of sense→forecast→plan→act→learn.

Trevor's learned performance models answer "what does a deployment achieve
at rate R?" in closed form — but every policy so far asked that question
about the rate that *already arrived*.  Phoebe's lesson (PAPERS.md) is that
a QoS-aware scaler should anticipate dynamic workloads and provision ahead
of the breach; Daedalus ties the same anticipation to resource efficiency.
A :class:`Forecaster` supplies the missing input: a window of expected
future loads (the forecast *horizon*) derived online from the sensed
history, so policies can plan for what is COMING rather than what just
happened.

Three families, from weakest to strongest prior:

* :class:`LastValueForecaster` — flat last-value / EWMA baseline: the
  degenerate horizon-1 assumption every reactive policy makes implicitly,
* :class:`HoltWintersForecaster` — online level + trend + optional
  additive seasonality (Holt-Winters), the right shape for the paper's
  diurnal/weekly traffic curves,
* :class:`ReplayForecaster` — seasonal-naive history replay ("the next
  hour looks like this hour yesterday"), the strongest cheap baseline for
  strongly periodic load.

All forecasters are *online*: feed one sample at a time through
``observe`` and ask for a window with ``forecast(h)`` at any point.  A
forecast is never negative.  Forecast-error tracking and online bias
correction live in :class:`repro.control.learning.ForecastTracker` — the
same predict-back-calibration idiom the node models get from
:class:`~repro.control.learning.ModelStore`.

Every forecaster also exposes ``state_dict()`` / ``load_state_dict()`` —
plain dicts of numpy-compatible leaves that round-trip *bit for bit*
through the :mod:`repro.checkpoint` layer, so a restarted controller
resumes with exactly the forecast state it crashed with (no cold-start
window, no re-learned seasonality)."""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class Forecaster(Protocol):
    """An online load forecaster: observe samples, emit a horizon window."""

    name: str

    def observe(self, load: float) -> None: ...

    def forecast(self, horizon: int) -> np.ndarray: ...


def _window(horizon: int) -> int:
    h = int(horizon)
    if h < 1:
        raise ValueError(f"forecast horizon must be >= 1, got {horizon}")
    return h


class LastValueForecaster:
    """Flat forecast: an EWMA of the history (``alpha=1`` = pure last value).

    The forecast window is constant at the current level — exactly the
    implicit assumption of every reactive policy, made explicit so it can
    be compared (and beaten) on equal terms.
    """

    def __init__(self, alpha: float = 1.0) -> None:
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = float(alpha)
        self.level: float | None = None
        self.name = "last-value" if alpha == 1.0 else f"ewma({alpha:g})"

    def observe(self, load: float) -> None:
        x = float(load)
        if self.level is None:
            self.level = x
        else:
            self.level = self.alpha * x + (1.0 - self.alpha) * self.level

    def forecast(self, horizon: int) -> np.ndarray:
        h = _window(horizon)
        level = 0.0 if self.level is None else max(self.level, 0.0)
        return np.full(h, level)

    def state_dict(self) -> dict:
        # "no level yet" is a distinct state from "level 0.0": a flag leaf
        # keeps the None round-trip exact
        return {
            "has_level": 1 if self.level is not None else 0,
            "level": 0.0 if self.level is None else float(self.level),
        }

    def load_state_dict(self, state: dict) -> None:
        self.level = (
            float(state["level"]) if int(state["has_level"]) else None
        )


class HoltWintersForecaster:
    """Online Holt-Winters: level + trend (+ additive seasonality).

    With ``season >= 2`` the forecaster carries one additive seasonal
    component per phase of the period — the diurnal/weekly shape.  Without
    a season it degrades to Holt's linear-trend smoothing (still ahead of
    last-value on ramps).  All three components update in O(1) per sample;
    seasonal slots start at zero, so the forecaster is usable from the
    first observation and sharpens as the history covers full periods.
    """

    def __init__(
        self,
        season: int | None = None,
        alpha: float = 0.5,
        beta: float = 0.2,
        gamma: float = 0.3,
    ) -> None:
        for nm, v in (("alpha", alpha), ("beta", beta), ("gamma", gamma)):
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{nm} must be in [0, 1], got {v}")
        self.season = int(season) if season and season >= 2 else 0
        self.alpha = float(alpha)
        self.beta = float(beta)
        self.gamma = float(gamma)
        self.level: float | None = None
        self.trend = 0.0
        self.seasonal = np.zeros(self.season)
        self._t = 0
        self.name = (
            f"holt-winters(season={self.season})" if self.season else "holt"
        )

    def observe(self, load: float) -> None:
        x = float(load)
        if self.level is None:
            self.level = x
            self._t = 1
            return
        s_old = self.seasonal[self._t % self.season] if self.season else 0.0
        prev = self.level
        self.level = (
            self.alpha * (x - s_old)
            + (1.0 - self.alpha) * (self.level + self.trend)
        )
        self.trend = (
            self.beta * (self.level - prev) + (1.0 - self.beta) * self.trend
        )
        if self.season:
            self.seasonal[self._t % self.season] = (
                self.gamma * (x - self.level) + (1.0 - self.gamma) * s_old
            )
        self._t += 1

    def forecast(self, horizon: int) -> np.ndarray:
        h = _window(horizon)
        if self.level is None:
            return np.zeros(h)
        k = np.arange(1, h + 1, dtype=np.float64)
        out = self.level + k * self.trend
        if self.season:
            out = out + self.seasonal[
                (self._t + np.arange(h) ) % self.season
            ]
        return np.maximum(out, 0.0)

    def state_dict(self) -> dict:
        return {
            "has_level": 1 if self.level is not None else 0,
            "level": 0.0 if self.level is None else float(self.level),
            "trend": float(self.trend),
            "seasonal": np.asarray(self.seasonal, np.float64),
            "t": int(self._t),
        }

    def load_state_dict(self, state: dict) -> None:
        self.level = (
            float(state["level"]) if int(state["has_level"]) else None
        )
        self.trend = float(state["trend"])
        seasonal = np.asarray(state["seasonal"], np.float64)
        if seasonal.shape != (self.season,):
            raise ValueError(
                f"seasonal state has {seasonal.shape[0]} slots, forecaster "
                f"has season={self.season}"
            )
        self.seasonal = seasonal.copy()
        self._t = int(state["t"])


class ReplayForecaster:
    """Seasonal-naive history replay: load ``k`` steps ahead is forecast as
    the load observed one ``period`` earlier (wrapping back additional whole
    periods when the horizon outruns the history).  Before a full period of
    history the last observed value stands in — so the forecaster is
    total from the first sample and converges to exact replay on strictly
    periodic traces.
    """

    name = "replay"

    def __init__(self, period: int, max_history: int | None = None) -> None:
        if int(period) < 1:
            raise ValueError(f"period must be >= 1, got {period}")
        self.period = int(period)
        #: ring-buffer bound: keep at least 2 periods so wrap-back resolves
        self.max_history = max(
            int(max_history) if max_history else 4 * self.period,
            2 * self.period,
        )
        self.history: list[float] = []

    def observe(self, load: float) -> None:
        self.history.append(float(load))
        if len(self.history) > self.max_history:
            del self.history[: len(self.history) - self.max_history]

    def forecast(self, horizon: int) -> np.ndarray:
        h = _window(horizon)
        n = len(self.history)
        if n == 0:
            return np.zeros(h)
        out = np.empty(h)
        for k in range(h):
            idx = n + k - self.period
            while idx >= n:                      # horizon outruns history
                idx -= self.period
            out[k] = self.history[idx] if idx >= 0 else self.history[-1]
        return np.maximum(out, 0.0)

    def state_dict(self) -> dict:
        return {"history": np.asarray(self.history, np.float64)}

    def load_state_dict(self, state: dict) -> None:
        self.history = [
            float(x) for x in np.asarray(state["history"], np.float64)
        ]


#: Name → zero-config factory (period-bearing forecasters take the season).
FORECASTERS: dict[str, type] = {
    "last-value": LastValueForecaster,
    "holt-winters": HoltWintersForecaster,
    "replay": ReplayForecaster,
}


def make_forecaster(name: str, **kw) -> Forecaster:
    """Build a registered forecaster by name (``KeyError`` on unknown)."""
    if name not in FORECASTERS:
        raise KeyError(
            f"unknown forecaster {name!r}; available: {sorted(FORECASTERS)}"
        )
    return FORECASTERS[name](**kw)
