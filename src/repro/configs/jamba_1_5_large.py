"""jamba-1.5-large-398b [hybrid]: 72L d=8192 64H (GQA kv=8) ff=24576
vocab=65536, Mamba+attention 1:7 interleave, MoE 16 experts top-2
[arXiv:2403.19887]."""
from .base import ModelConfig, SSMConfig, register, register_smoke

# period of 8: attention at index 3, mamba elsewhere; MoE every 2nd layer
_PATTERN = ("mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba", "mamba")


@register
def jamba_1_5_large() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=24576, vocab=65536, head_dim=128,
        n_experts=16, experts_per_token=2, moe_every=2,
        block_pattern=_PATTERN, ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
        notes="9/72 attention layers; long_500k decode uses sequence-sharded KV",
    )


register_smoke("jamba-1.5-large-398b", lambda: ModelConfig(
    name="jamba-1.5-large-398b@smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    head_dim=16, n_experts=4, experts_per_token=2, moe_every=2,
    block_pattern=("mamba", "attn"), ssm=SSMConfig(d_state=4, d_conv=2, chunk=16),
))
