"""mixtral-8x7b [moe]: 32L d=4096 32H (GQA kv=8) ff=14336 vocab=32000,
8 experts top-2, sliding-window attention [arXiv:2401.04088]."""
from .base import ModelConfig, register, register_smoke


@register
def mixtral_8x7b() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=32000, head_dim=128,
        n_experts=8, experts_per_token=2, moe_every=1,
        sliding_window=4096, rope_theta=1_000_000.0,
        notes="8 experts < tp=16: expert-TP sharding (DESIGN.md §5); SWA => long_500k",
    )


register_smoke("mixtral-8x7b", lambda: ModelConfig(
    name="mixtral-8x7b@smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    head_dim=16, n_experts=4, experts_per_token=2, moe_every=1, sliding_window=32,
))
