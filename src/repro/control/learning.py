"""Unified learning layer of the control plane (Trevor §4).

Calibration, drift detection and retraining used to be spread across
``AutoScaler`` (observe/retrain), ``Calibrator`` (records + factor) and the
benchmarks (ad-hoc pooling).  :class:`ModelStore` is the single owner now:
it pools measurements from *any* evaluation engine, exposes the
over-provisioning factor to every policy, and — on drift — refits the node
models from the pooled Heron-style metrics.

:func:`fold_executor_timings` closes the standing ROADMAP loop between the
two evaluation backends: operator timings measured by the real-JAX executor
are folded back into the simulator's physical truth (calibrated per-node
costs + a host-speed-scaled stream-manager cost in :class:`SimParams`), so
drift experiments can replay "the same pipeline, on this machine" through
the batched simulator.

:class:`ForecastTracker` extends the same predict-back idiom to the
forecast phase: one-step-ahead forecasts are scored against the sensed
load, and a persistent bias becomes a multiplicative correction factor on
future forecast windows — online refinement for the forecaster, exactly
as the calibrator's over-provisioning factor refines the node models.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Mapping, Sequence

import numpy as np

from ..core.calibration import Calibrator
from ..core.dag import Configuration, DagSpec
from ..core.metrics import MetricsStore
from ..core.node_model import LinearFit, NodeModel, ResourceClass, fit_workload

if TYPE_CHECKING:
    from ..streams.engine import ExecutorEvaluator
    from ..streams.simulator import SimParams


class ModelStore:
    """Pools measurements, owns the node models and the calibration state.

    Every policy reads ``models`` and ``overprovision_factor`` from here;
    every evaluator's measurements come back through ``observe`` /
    ``observe_many`` (predict-back calibration) and ``pool`` (raw metric
    timeseries for retraining).  When the calibrator declares drift,
    :meth:`retrain` refits every node model from the pooled metrics — the
    paper's "keep pooling metrics and improve model performance" loop.
    """

    def __init__(
        self,
        models: Mapping[str, NodeModel],
        calibrator: Calibrator | None = None,
        max_pooled_samples: int = 4096,
    ) -> None:
        self.models = dict(models)
        self.calibrator = calibrator or Calibrator()
        self.metrics = MetricsStore()
        self.max_pooled_samples = max_pooled_samples
        #: monotonic mutation counter: bumped whenever calibration state or
        #: the node models change, so downstream memos (the fleet
        #: scheduler's candidate-ladder cache, the engine layer's
        #: evaluation ResultCache via ``version_source``) can key on it
        #: instead of hashing model contents every replan — a bump makes
        #: every result computed under the old models unreachable
        self.version = 0

    # -- calibration (predict-back, §4) -------------------------------------
    @property
    def overprovision_factor(self) -> float:
        return self.calibrator.overprovision_factor

    def observe(self, config: Configuration, measured_ktps: float) -> bool:
        """Record one predicted-vs-measured pair; returns the drift flag."""
        self.calibrator.observe(config, self.models, measured_ktps)
        self.version += 1
        return self.drift_detected()

    def observe_many(
        self, configs: Sequence[Configuration], measured_ktps: Sequence[float]
    ) -> bool:
        """Batch form — the natural sink for ``evaluate_batch`` output and
        for the control loop's buffered saturated measurements."""
        self.calibrator.observe_many(configs, self.models, measured_ktps)
        self.version += 1
        return self.drift_detected()

    def drift_detected(self) -> bool:
        return self.calibrator.drift_detected()

    @property
    def retrain_count(self) -> int:
        return self.calibrator.retrain_count

    # -- metric pooling + retraining ----------------------------------------
    def pool(self, store: MetricsStore) -> None:
        """Accumulate Heron-style metric timeseries (bounded: oldest samples
        are dropped once ``max_pooled_samples`` instance-series are held)."""
        self.metrics.extend(store)
        excess = len(self.metrics) - self.max_pooled_samples
        if excess > 0:
            self.metrics.samples = self.metrics.samples[excess:]

    def retrain(self, store: MetricsStore | None = None) -> dict[str, NodeModel] | None:
        """Refit every node model from ``store`` (default: the pooled
        metrics) and reset the calibration window.  Returns the refit models,
        or None when there is nothing to fit from."""
        src = store if store is not None else self.metrics
        if len(src) == 0:
            return None
        fitted = fit_workload(src)
        self.models.update(fitted)
        self.calibrator.mark_retrained()
        self.version += 1
        return fitted

    # -- checkpointing -------------------------------------------------------
    def state_dict(self) -> dict:
        """Everything a restarted controller needs to resume *warm*, as a
        nested dict of numpy-compatible leaves: the node models (exact
        float64 fit parameters), the calibration records behind the
        over-provisioning factor, and the monotonic ``version`` counter —
        the token every downstream memo (candidate ladders, the engine's
        ResultCache) keys on, so cached results stay exactly as (in)valid
        after a restart as before it.  Pooled raw metrics are NOT
        serialized: they are a bounded re-fillable buffer, not control
        state."""
        models: dict = {}
        for name, m in self.models.items():
            if "/" in name:
                raise ValueError(
                    f"node name {name!r} contains '/', which the checkpoint "
                    "tree layout reserves as its key separator"
                )
            models[name] = {
                "cpu": np.asarray(
                    [m.cpu.slope, m.cpu.intercept, m.cpu.r2,
                     m.cpu.x_min, m.cpu.x_max], np.float64
                ),
                "cap": np.asarray(
                    [m.cap.slope, m.cap.intercept, m.cap.r2,
                     m.cap.x_min, m.cap.x_max], np.float64
                ),
                "scalars": np.asarray(
                    [m.gamma, m.gamma_r2, m.mem_base_mb,
                     m.mem_slope_mb_per_ktps], np.float64
                ),
                "resource_class": str(m.resource_class.value),
                "n_samples": int(m.n_samples),
            }
        return {
            "version": int(self.version),
            "models": models,
            "calibrator": self.calibrator.state_dict(),
        }

    def load_state_dict(self, state: dict) -> None:
        """Inverse of :meth:`state_dict` — restores the node models, the
        calibration window and the version counter bit-for-bit (the
        restored store predicts, provisions and cache-keys exactly like
        the one that was saved)."""
        models: dict[str, NodeModel] = {}
        for name, s in state["models"].items():
            cpu = np.asarray(s["cpu"], np.float64)
            cap = np.asarray(s["cap"], np.float64)
            scalars = np.asarray(s["scalars"], np.float64)
            models[name] = NodeModel(
                name=name,
                cpu=LinearFit(*(float(x) for x in cpu)),
                cap=LinearFit(*(float(x) for x in cap)),
                gamma=float(scalars[0]),
                gamma_r2=float(scalars[1]),
                mem_base_mb=float(scalars[2]),
                mem_slope_mb_per_ktps=float(scalars[3]),
                resource_class=ResourceClass(str(s["resource_class"])),
                n_samples=int(s["n_samples"]),
            )
        self.models = models
        self.calibrator.load_state_dict(state["calibrator"])
        self.version = int(state["version"])


class ForecastTracker:
    """Predict-back calibration for forecasters (the §4 idiom, applied to
    the forecast phase).

    The control loop records each step's one-step-ahead forecast and, one
    step later, the load that actually arrived.  Over a sliding window the
    tracker exposes the forecast accuracy (:meth:`mean_abs_pct_error`) and
    a clipped multiplicative correction (:meth:`factor`): a forecaster that
    persistently under-predicts by 10% gets its windows scaled up by ~1.1
    before planning — the forecaster analogue of the calibrator's
    over-provisioning factor, learned online and never trusted beyond
    ``max_correction``.
    """

    def __init__(self, window: int = 32, max_correction: float = 1.5) -> None:
        if int(window) < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.window = int(window)
        self.max_correction = float(max_correction)
        self.predicted: list[float] = []
        self.actual: list[float] = []

    def __len__(self) -> int:
        return len(self.actual)

    def observe(self, predicted: float, actual: float) -> None:
        """Record one (one-step-ahead forecast, sensed load) pair."""
        self.predicted.append(float(predicted))
        self.actual.append(float(actual))
        bound = 4 * self.window
        if len(self.actual) > bound:
            del self.predicted[:-bound]
            del self.actual[:-bound]

    def _recent(self) -> tuple[np.ndarray, np.ndarray]:
        p = np.asarray(self.predicted[-self.window :], np.float64)
        a = np.asarray(self.actual[-self.window :], np.float64)
        return p, a

    def mean_abs_pct_error(self) -> float:
        """Mean |actual - predicted| / actual over the window (NaN-free:
        zero-load steps are excluded)."""
        p, a = self._recent()
        mask = a > 1e-9
        if not mask.any():
            return 0.0
        return float(np.mean(np.abs(a[mask] - p[mask]) / a[mask]))

    def bias(self) -> float:
        """Signed mean (actual - predicted) / actual: positive = the
        forecaster under-predicts (the dangerous direction)."""
        p, a = self._recent()
        mask = a > 1e-9
        if not mask.any():
            return 0.0
        return float(np.mean((a[mask] - p[mask]) / a[mask]))

    def factor(self) -> float:
        """Multiplicative window correction: mean actual/predicted ratio
        over the window, clipped to [1/max_correction, max_correction]."""
        p, a = self._recent()
        mask = p > 1e-9
        if not mask.any():
            return 1.0
        ratio = float(np.mean(a[mask] / p[mask]))
        return float(
            np.clip(ratio, 1.0 / self.max_correction, self.max_correction)
        )


def fold_executor_timings(
    dag: DagSpec,
    evaluator: "ExecutorEvaluator | None" = None,
    params: "SimParams | None" = None,
    n_batches: int = 5,
    floor_ktps: float = 50.0,
) -> tuple[DagSpec, "SimParams"]:
    """Fold real-executor operator timings into the simulator's physics.

    Returns ``(calibrated_dag, calibrated_params)``: the DAG's ground-truth
    per-ktuple costs become the wall-clock costs measured on this host, and
    ``SimParams.sm_cost_per_ktuple`` is rescaled by the median host-speed
    ratio (measured/spec cost over the timed operators) so the simulated
    stream managers slow down (or speed up) with the node bodies.  Feeding
    the result to a :class:`~repro.streams.engine.SimulatorEvaluator` yields
    a simulator that drifts exactly as this host drifts — the missing link
    for executor-in-the-loop drift experiments.
    """
    from ..streams.simulator import SimParams
    import dataclasses

    if params is None:
        params = SimParams()
    if evaluator is not None:
        cal = evaluator.calibrated_dag(dag)
    else:
        from ..streams.executor import calibrate_dag

        cal = calibrate_dag(dag, n_batches=n_batches, floor_ktps=floor_ktps)
    ratios = [
        b.cpu_cost_per_ktuple / a.cpu_cost_per_ktuple
        for a, b in zip(dag.nodes, cal.nodes)
        if a.cpu_cost_per_ktuple > 0 and b.cpu_cost_per_ktuple != a.cpu_cost_per_ktuple
    ]
    scale = float(np.median(ratios)) if ratios else 1.0
    new_params = dataclasses.replace(
        params, sm_cost_per_ktuple=params.sm_cost_per_ktuple * scale
    )
    return cal, new_params
