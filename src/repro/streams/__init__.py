"""Stream-processing substrate: operators, workloads, load sources, the
batched discrete-time cluster simulator, the real JAX executor, and the
engine abstraction that lets control layers evaluate configurations without
knowing which backend answers."""

from .workloads import (
    WORKLOADS,
    adanalytics,
    deep_pipeline,
    diamond,
    mobile_analytics,
    wordcount,
)
from .simulator import (
    DEGREE_LADDER,
    EDGE_LADDER,
    SAMPLES_MODES,
    SimParams,
    SimResult,
    TrajectoryUnavailable,
    batch_bucket_size,
    bucket_size,
    clear_dedup_stats,
    clear_kernel_cache,
    clear_resident_cache,
    clear_structure_cache,
    clear_transfer_stats,
    dedup_info,
    degree_bucket_size,
    edge_bucket_size,
    kernel_cache_info,
    measure_capacity,
    pad_structure,
    resident_cache_info,
    resolve_tick_kernel,
    shard_count,
    simulate,
    simulate_batch,
    simulate_grid,
    structure_cache_info,
    training_sweep,
    transfer_info,
)
from .cache import (
    ResultCache,
    cache_stats,
    clear_result_caches,
    result_cache_info,
)
from .engine import (
    OVERLOAD_KTPS,
    ConfigEvaluator,
    EvalResult,
    ExecutorEvaluator,
    PerCandidateLoads,
    SimulatorEvaluator,
    evaluate_grid_with,
    evaluate_jobs_with,
)
from . import sources

__all__ = [
    "DEGREE_LADDER",
    "EDGE_LADDER", "SAMPLES_MODES", "WORKLOADS", "ConfigEvaluator",
    "EvalResult",
    "ExecutorEvaluator",
    "OVERLOAD_KTPS", "PerCandidateLoads", "ResultCache", "SimParams",
    "SimResult",
    "SimulatorEvaluator", "TrajectoryUnavailable",
    "adanalytics", "batch_bucket_size", "bucket_size", "cache_stats",
    "clear_dedup_stats", "clear_kernel_cache",
    "clear_resident_cache", "clear_result_caches", "clear_structure_cache",
    "clear_transfer_stats",
    "dedup_info", "deep_pipeline",
    "degree_bucket_size",
    "diamond", "edge_bucket_size", "evaluate_grid_with", "evaluate_jobs_with",
    "kernel_cache_info", "measure_capacity", "mobile_analytics",
    "pad_structure", "resident_cache_info", "resolve_tick_kernel",
    "result_cache_info",
    "shard_count", "simulate", "simulate_batch",
    "simulate_grid", "sources", "structure_cache_info", "training_sweep",
    "transfer_info",
    "wordcount",
]
