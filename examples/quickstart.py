"""Quickstart: the full Trevor workflow on WordCount in one minute.

1. deploy a test configuration on the (simulated) cluster,
2. sweep a throttled producer to collect runtime metrics (§5.1),
3. fit per-node models — CPU~rate, capacity, γ — incl. the stream manager,
4. predict the rate of unseen configurations (fig. 13),
5. declare a target rate -> one-shot allocation (fig. 2b),
6. verify the allocation on the cluster.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    STREAM_MANAGER,
    Configuration,
    ContainerDim,
    allocate,
    fit_workload,
    round_robin_configuration,
    solve_flow,
)
from repro.streams import SimParams, measure_capacity, training_sweep, wordcount

DIM = ContainerDim(cpus=3.0, mem_mb=4096.0)


def main() -> None:
    dag = wordcount()
    params = SimParams()

    print("== 1-2. profile a test deployment over a range of rates ==")
    test_cfg = round_robin_configuration(dag, {"W": 1, "C": 1}, 2, DIM)
    store = training_sweep(test_cfg, rates_ktps=np.linspace(50, 300, 6),
                           params=params, seconds_per_rate=8.0)
    print(f"collected {len(store)} instance timeseries "
          f"({len(store.nodes())} logical nodes incl. stream manager)")

    print("\n== 3. fit node models ==")
    models = fit_workload(store)
    for name, m in sorted(models.items()):
        print(f"  {name:22s} peak={m.peak_rate_ktps:7.1f} ktps  "
              f"γ={m.gamma:4.2f}  cpuR²={m.cpu.r2:.3f}  [{m.resource_class.value}]")

    print("\n== 4. predict unseen configurations ==")
    for packing in [(("W",), ("C",)), (("W", "C"), ("W", "C")),
                    (("W",), ("W",), ("C",), ("C",))]:
        cfg = Configuration(dag, packing=packing, dims=(DIM,) * len(packing))
        pred = solve_flow(cfg, models).rate_ktps
        meas = measure_capacity(cfg, params, duration_s=10.0)
        print(f"  {cfg.describe():55s} pred {pred:7.1f}  measured {meas:7.1f}  "
              f"err {abs(pred-meas)/meas*100:4.1f}%")

    print("\n== 5. declare a target: 2,000 ktps ==")
    result = allocate(dag, models, 2000.0, overprovision=1.1)
    print(f"  allocator -> {result.config.n_containers} containers, "
          f"{result.total_cpus:.1f} CPUs")
    for t in result.templates:
        print(f"    balanced container {t.nodes}: {t.counts} "
              f"@ {t.rate_ktps:.0f} ktps ×{t.replicas} replicas "
              f"(SM traversal factor {t.sm_traversal_factor:.2f})")

    print("\n== 6. verify on the cluster ==")
    achieved = measure_capacity(result.config, params, duration_s=12.0)
    print(f"  achieved {achieved:.0f} ktps for target 2000 ktps "
          f"({'OK' if achieved >= 1800 else 'UNDER'})")


if __name__ == "__main__":
    main()
