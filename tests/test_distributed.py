"""Distributed-execution tests on a subprocess with 8 fake host devices:
real (not just lowered) sharded train steps, sharding-plan invariants,
compressed all-reduce under shard_map, and serving."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sharded_train_step_executes_and_matches_single_device():
    """A 2x2-mesh sharded train step produces the same loss as the
    single-device step (DP+TP correctness, executed not just compiled)."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, json
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, ShapeConfig
        from repro.models import build_model
        from repro.models.common import axis_rules, param_specs
        from repro.launch import sharding as shlib
        from repro.launch.mesh import make_debug_mesh

        cfg = get_config("llama3-8b@smoke")
        shape = ShapeConfig("t", 64, 4, "train")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0, cfg.vocab),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 64), 0, cfg.vocab),
        }
        ref, _ = jax.jit(model.loss_fn)(params, batch)

        mesh = make_debug_mesh(2, 2)
        plan = shlib.PlanConfig(tp=2, dp=2)
        rules = shlib.make_rules(cfg, shape, plan)
        pspecs = param_specs(model.defs(), rules)
        with jax.set_mesh(mesh):
            p_sh = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, NamedSharding(mesh, s)), params, pspecs)
            b_sh = jax.tree_util.tree_map(
                lambda a: jax.device_put(a, NamedSharding(mesh, P("data", None))), batch)
            def lf(p, b):
                with axis_rules(rules):
                    return model.loss_fn(p, b)
            loss, _ = jax.jit(lf)(p_sh, b_sh)
        print(json.dumps({"ref": float(ref), "sharded": float(loss)}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["sharded"] == pytest.approx(res["ref"], rel=2e-4)


def test_moe_ep_matches_unsharded():
    """Expert-parallel MoE (experts over 'model') == single-device result."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, json, dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models.moe import moe_defs, moe_ffn
        from repro.models.common import axis_rules, init_params
        from repro.launch.mesh import make_debug_mesh

        cfg = dataclasses.replace(get_config("olmoe-1b-7b@smoke"), capacity_factor=8.0)
        defs = {"moe": moe_defs(cfg, 1)}
        params = jax.tree_util.tree_map(lambda a: a[0],
                                        init_params(defs, jax.random.PRNGKey(0))["moe"])
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        y_ref, _ = moe_ffn(params, x, cfg)

        mesh = make_debug_mesh(2, 4)  # experts (8) % tp (4) == 0 -> EP
        rules = {"experts": "model", "experts_act": "model",
                 "expert_ff": None, "expert_act_ff": None,
                 "act_batch": "data", "act_ff": None}
        with jax.set_mesh(mesh):
            shard = lambda a, s: jax.device_put(a, NamedSharding(mesh, s))
            p_sh = {
                "router": shard(params["router"], P(None, None)),
                "w1": shard(params["w1"], P("model", None, None)),
                "w3": shard(params["w3"], P("model", None, None)),
                "w2": shard(params["w2"], P("model", None, None)),
            }
            x_sh = shard(x, P("data", None, None))
            def f(p, x):
                with axis_rules(rules):
                    return moe_ffn(p, x, cfg)[0]
            y = jax.jit(f)(p_sh, x_sh)
        import numpy as np
        print(json.dumps({"err": float(jnp.abs(y - y_ref).max())}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["err"] < 1e-4


def test_compressed_allreduce_under_shard_map():
    """Top-k + error-feedback all-reduce across the data axis approximates
    the dense mean gradient."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, json
        from functools import partial
        from jax.sharding import NamedSharding, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.optim.compression import TopKConfig, topk_allreduce
        from repro.launch.mesh import make_debug_mesh

        mesh = make_debug_mesh(8, 1)
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 128))  # one row per worker
        dense_mean = g.mean(0)

        @partial(shard_map, mesh=mesh, in_specs=(P("data", None),),
                 out_specs=P("data", None))
        def compressed(gl):
            e0 = jnp.zeros_like(gl[0])
            out, _ = topk_allreduce(gl[0], e0, TopKConfig(density=0.5), "data")
            return out[None]

        approx = compressed(g)[0]
        cos = float(jnp.sum(approx * dense_mean) /
                    (jnp.linalg.norm(approx) * jnp.linalg.norm(dense_mean) + 1e-9))
        print(json.dumps({"cos": cos}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["cos"] > 0.8


def test_seqsharded_flash_decode_matches_dense():
    """The long-context flash-decoding path (sequence-sharded KV + psum)
    equals dense decode attention."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, json
        from functools import partial
        from jax.sharding import NamedSharding, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.configs import get_config
        from repro.models.attention import gqa_defs, gqa_decode, gqa_decode_seqsharded
        from repro.models.common import init_params
        from repro.launch.mesh import make_debug_mesh
        import dataclasses

        cfg = dataclasses.replace(get_config("llama3-8b@smoke"), sliding_window=None)
        defs = {"a": gqa_defs(cfg, 1)}
        p = jax.tree_util.tree_map(lambda a: a[0],
                                   init_params(defs, jax.random.PRNGKey(0))["a"])
        B, T = 2, 64
        cache = {
            "k": jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.n_kv_heads, cfg.head_dim)),
            "v": jax.random.normal(jax.random.PRNGKey(2), (B, T, cfg.n_kv_heads, cfg.head_dim)),
        }
        x = jax.random.normal(jax.random.PRNGKey(3), (B, 1, cfg.d_model)) * 0.3
        pos = jnp.asarray(T - 1, jnp.int32)
        ref, _ = gqa_decode(p, x, cfg, {k: v.copy() for k, v in cache.items()}, pos)

        mesh = make_debug_mesh(8, 1)
        @partial(shard_map, mesh=mesh,
                 in_specs=(P(), P(None, None, None), {"k": P(None, "data", None, None),
                                                      "v": P(None, "data", None, None)}, P()),
                 out_specs=P(None, None, None), check_rep=False)
        def sharded(p, x, cache, pos):
            out, _ = gqa_decode_seqsharded(p, x, cfg, cache, pos, axis_name="data")
            return out

        got = sharded(p, x, cache, pos)
        print(json.dumps({"err": float(jnp.abs(got - ref).max())}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["err"] < 2e-3


def test_server_end_to_end():
    from repro.launch.serve import BatchedServer, Request
    import numpy as np

    server = BatchedServer("stablelm-1.6b@smoke", batch_slots=2, max_ctx=64)
    rng = np.random.default_rng(0)
    for rid in range(4):
        prompt = rng.integers(4, 250, size=12).astype(np.int32)
        server.submit(Request(rid, prompt, max_new_tokens=6))
    server.drain()
    assert len(server.completed) == 4
    for r in server.completed:
        assert len(r.tokens_out) == 6
        assert all(0 <= t < server.cfg.padded_vocab for t in r.tokens_out)
