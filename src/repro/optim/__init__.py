from .optimizer import AdamWConfig, adamw_update, cosine_lr, global_norm, init_opt_state
from . import compression

__all__ = ["AdamWConfig", "adamw_update", "compression", "cosine_lr",
           "global_norm", "init_opt_state"]
