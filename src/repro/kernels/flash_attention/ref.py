"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_reference(
    q: jax.Array,                    # (B, H, S, hd)
    k: jax.Array,                    # (B, KV, S, hd)
    v: jax.Array,                    # (B, KV, S, hd)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    if scale is None:
        scale = hd ** -0.5
    qg = q.reshape(B, KV, G, S, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqh,bkth->bkgqt", qg, kf) * scale
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,bkth->bkgqh", p, vf)
    return o.reshape(B, H, S, hd).astype(q.dtype)
