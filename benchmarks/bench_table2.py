"""Paper Table 2: WordCount performance under different configurations —
simulated ground truth, Trevor's predicted rate, and the bound column."""
from __future__ import annotations

from repro.core import Configuration, ContainerDim, classify_bound, oracle_models, solve_flow
from repro.streams import SimParams, measure_capacity, wordcount

from .common import emit, timed

PAPER = {  # id: (packing, paper ktps, paper bound)
    1: ((("W",), ("C",)), 658, "~Rc"),
    2: ((("W", "C"), ("W", "C")), 965, "comm"),
    3: ((("W", "W"), ("C", "C")), 648, "comm"),
    5: ((("W",), ("C",), ("C",)), 899, "~Rw"),
    6: ((("W",), ("W",), ("C",), ("C",)), 1319, "2xRc"),
    7: ((("W",), ("W",), ("C",), ("C",), ("C",)), 1779, "2xRw"),
    8: ((("W",), ("W",), ("C",), ("C",), ("C",), ("C",)), 1847, "2xRw"),
    9: ((("W",), ("W",), ("C",), ("C",), ("C",), ("C",), ("C",)), 1582, "drop"),
}


def run() -> dict:
    dag = wordcount()
    params = SimParams()
    models = oracle_models(dag, params.sm_cost_per_ktuple)
    dim = ContainerDim(cpus=3.0, mem_mb=4096.0)
    rows = []
    errs = []
    print("# id, sim_ktps, pred_ktps, err%, bound, paper_ktps")
    for cid, (packing, paper_rate, paper_bound) in PAPER.items():
        cfg = Configuration(dag, packing=packing, dims=(dim,) * len(packing))
        sim = measure_capacity(cfg, params, duration_s=15.0)
        sol, us = timed(solve_flow, cfg, models, repeats=3)
        err = abs(sol.rate_ktps - sim) / max(sim, 1) * 100
        errs.append(err)
        bound = classify_bound(sol)
        rows.append((cid, sim, sol.rate_ktps, err, bound, paper_rate))
        print(f"# ID={cid}: sim {sim:7.1f}  pred {sol.rate_ktps:7.1f}  "
              f"err {err:4.1f}%  bound={bound:12s} paper={paper_rate} ({paper_bound})")
        emit(f"table2_id{cid}_predict", us, f"pred={sol.rate_ktps:.0f}ktps;err={err:.1f}%")
    mean_err = sum(errs) / len(errs)
    emit("table2_mean_prediction_error", 0.0, f"{mean_err:.1f}%_(paper:<10%)")
    return {"rows": rows, "mean_err": mean_err}


if __name__ == "__main__":
    run()
