"""Paper Fig. 14: allocator efficiency.

(a) configure AdAnalytics for increasing target rates; achieved (simulated)
    vs target with the ~10-20% over-provisioning margin,
(b/c) CPU usage vs rate: Trevor allocation vs round-robin paths (I instances
    per container) vs the optimal line (single unbounded container, free SM).
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    ContainerDim,
    allocate,
    oracle_models,
    round_robin_configuration,
    single_container_configuration,
    solve_flow,
)
from repro.streams import SimParams, adanalytics, measure_capacity, wordcount

from .common import emit, timed

DIM = ContainerDim(cpus=3.0, mem_mb=4096.0)


def _optimal_cpus(dag, models, rate: float) -> float:
    """Optimal line: all instances in one unbounded container (the paper's
    construction) — pure compute, plus the *unavoidable* communication floor:
    every tuple on every edge traverses exactly one (local) stream manager."""
    from repro.core import STREAM_MANAGER, propagate_rates

    node_rates = propagate_rates(dag, rate, {n: models[n].gamma for n in dag.node_names})
    compute = sum(
        models[n].cpu_cost_per_ktps * node_rates[n] for n in dag.node_names
    )
    edge_flow = sum(
        node_rates[e.src] * models[e.src].gamma for e in dag.edges
    )
    sm_floor = models[STREAM_MANAGER].cpu_cost_per_ktps * edge_flow
    return compute + sm_floor


def _round_robin_cpus(dag, models, rate: float, inst_per_container: int) -> float:
    for p in range(1, 48):
        par = {n: p for n in dag.node_names}
        n_cont = max(1, -(-sum(par.values()) // inst_per_container))
        cfg = round_robin_configuration(dag, par, n_cont, DIM)
        if solve_flow(cfg, models).rate_ktps >= rate:
            return cfg.total_cpus()
    return float("nan")


def run() -> dict:
    params = SimParams()
    out = {}

    # (a) achieved vs target with overprovisioning
    dag = adanalytics()
    models = oracle_models(dag, params.sm_cost_per_ktuple)
    print("# target, achieved(sim), containers, cpus")
    hits = []
    for target in (250.0, 500.0, 750.0, 1000.0):
        res, us = timed(allocate, dag, models, target, repeats=1, warmup=0,
                        overprovision=1.15)
        achieved = measure_capacity(res.config, params, duration_s=12.0)
        hits.append(achieved >= target * 0.9)
        print(f"# {target:6.0f}  {achieved:7.1f}  {res.config.n_containers:4d}  "
              f"{res.total_cpus:6.1f}")
        emit(f"fig14a_target{int(target)}", us,
             f"achieved={achieved:.0f};hit={achieved >= target * 0.9}")
    out["hits"] = hits

    # (b) WordCount CPU-vs-rate and (c) AdAnalytics CPU-vs-rate
    for name, dg in (("fig14b_wordcount", wordcount()), ("fig14c_adanalytics", adanalytics())):
        mdl = oracle_models(dg, params.sm_cost_per_ktuple)
        print(f"# {name}: rate, optimal_cpus, trevor_cpus, rr1, rr2, rr3")
        ratios = []
        for rate in (400.0, 800.0, 1200.0):
            opt = _optimal_cpus(dg, mdl, rate)
            tv = allocate(dg, mdl, rate).total_cpus
            rr = [_round_robin_cpus(dg, mdl, rate, i) for i in (1, 2, 3)]
            ratios.append(tv / opt)
            print(f"# {rate:6.0f}  {opt:7.1f}  {tv:7.1f}  "
                  + "  ".join(f"{r:7.1f}" for r in rr))
        best_rr = min(r for r in rr if np.isfinite(r))
        emit(name, 0.0,
             f"trevor/optimal={np.mean(ratios):.2f}x;trevor_vs_best_rr="
             f"{tv/best_rr:.2f}x_(paper:<=1.1x_of_optimal_on_complex_DAGs)")
        out[name] = ratios
    return out


if __name__ == "__main__":
    run()
