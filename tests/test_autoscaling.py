"""Auto-scaler + reactive (Dhalion-style) baseline behaviour."""
import numpy as np
import pytest

from repro.core import (
    AutoScaler,
    Configuration,
    ContainerDim,
    oracle_models,
    reactive_scale,
    solve_flow,
)
from repro.streams import SimParams, measure_capacity, simulate, sources, wordcount

DIM = ContainerDim(cpus=3.0, mem_mb=4096.0)
PARAMS = SimParams()


def _models(dag):
    return oracle_models(dag, PARAMS.sm_cost_per_ktuple)


def test_autoscaler_single_shot_configures_for_target():
    dag = wordcount()
    scaler = AutoScaler(dag, _models(dag))
    res = scaler.configure_for(2000.0)
    sol = solve_flow(res.config, _models(dag))
    assert sol.rate_ktps >= 2000.0 * 0.999
    assert scaler.mean_alloc_seconds() < 1.0  # the paper's sub-second claim


def test_autoscaler_deadband_prevents_flapping():
    dag = wordcount()
    scaler = AutoScaler(dag, _models(dag), deadband=0.15)
    scaler.configure_for(1000.0)
    n0 = scaler.reconfigurations
    assert scaler.observe_load(1000.0 / scaler.headroom * 1.02) is None
    assert scaler.reconfigurations == n0
    assert scaler.observe_load(3000.0) is not None
    assert scaler.reconfigurations == n0 + 1


def test_autoscaler_follows_spike_trace():
    dag = wordcount()
    scaler = AutoScaler(dag, _models(dag))
    trace = sources.spike(20, base_ktps=400.0, spike_ratio=8.0, seed=1)
    cpus = []
    for load in trace:
        scaler.observe_load(float(load))
        cpus.append(scaler.current.total_cpus)
    cpus = np.asarray(cpus)
    # provisioning scales up through the spike and back down after
    assert cpus.max() > cpus[0] * 2
    assert cpus[-1] < cpus.max() * 0.7


def test_reactive_baseline_converges_slower_than_one_shot():
    """The paper's core comparison: Dhalion-style iteration needs many deploy
    cycles; Trevor needs one allocator call."""
    dag = wordcount()
    target = 1500.0

    def measure(cfg: Configuration):
        res = simulate(cfg, 1e6, duration_s=8.0, params=PARAMS)
        return res.achieved_ktps, res.bottleneck_node()

    reactive = reactive_scale(dag, target, measure, dim=DIM, max_iterations=24)
    assert reactive.converged
    assert reactive.iterations >= 3  # several deploy cycles
    # 2 min per deploy cycle -> tens of minutes, vs sub-second for Trevor
    assert reactive.convergence_seconds >= 3 * 120

    scaler = AutoScaler(dag, _models(dag))
    res = scaler.configure_for(target)
    assert scaler.mean_alloc_seconds() < 1.0
    achieved = measure_capacity(res.config, PARAMS, duration_s=10.0)
    assert achieved >= target * 0.85  # models are approximate; calibration closes the rest


def test_trevor_allocation_is_not_less_efficient_than_reactive():
    dag = wordcount()
    target = 1200.0

    def measure(cfg: Configuration):
        res = simulate(cfg, 1e6, duration_s=8.0, params=PARAMS)
        return res.achieved_ktps, res.bottleneck_node()

    reactive = reactive_scale(dag, target, measure, dim=DIM, max_iterations=24)
    scaler = AutoScaler(dag, _models(dag))
    trevor = scaler.configure_for(target)
    if reactive.converged:
        assert trevor.total_cpus <= reactive.final_config.total_cpus() * 1.25
