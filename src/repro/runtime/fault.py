"""Fault tolerance: failure injection, restart-from-checkpoint, and
straggler mitigation — the runtime half of "large-scale runnability".

On a real multi-pod deployment the coordinator (jax.distributed) detects a
missing host; here the same control flow is exercised by injecting failures
into the training driver and asserting exact-resume semantics (tests in
``tests/test_fault_tolerance.py``):

* **checkpoint/restart** — deterministic data pipeline + atomic sharded
  checkpoints mean a restart reproduces the uninterrupted loss trajectory
  bit-for-bit (same batch at same step),
* **straggler mitigation** — per-step wall-time is tracked with a robust
  (median + MAD) deadline; steps exceeding it are flagged and the policy
  hook fires (on TPU pods: re-dispatch the slice / evict the straggler;
  here: recorded + surfaced so the elastic layer can re-mesh),
* **elastic restart** — checkpoints are consolidated (host layout), so a
  job restarted with a different mesh reshards transparently.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable


class InjectedFailure(RuntimeError):
    """Stand-in for a host/TPU failure."""


@dataclasses.dataclass
class FailurePlan:
    """Deterministic failure schedule: fail right *after* step N executes
    (models a machine dying mid-run; the step's effects are lost unless
    checkpointed)."""

    fail_after_steps: tuple[int, ...] = ()
    triggered: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_after_steps and step not in self.triggered:
            self.triggered.add(step)
            raise InjectedFailure(f"injected failure after step {step}")


@dataclasses.dataclass
class StragglerMonitor:
    """Robust per-step deadline: median + k * MAD over a sliding window."""

    window: int = 32
    k: float = 6.0
    min_samples: int = 8
    times: list = dataclasses.field(default_factory=list)
    stragglers: list = dataclasses.field(default_factory=list)
    on_straggler: Callable[[int, float, float], None] | None = None

    def observe(self, step: int, seconds: float) -> bool:
        ts = self.times[-self.window:]
        is_straggler = False
        if len(ts) >= self.min_samples:
            med = sorted(ts)[len(ts) // 2]
            mad = sorted(abs(t - med) for t in ts)[len(ts) // 2]
            deadline = med + self.k * max(mad, 0.05 * med)
            if seconds > deadline:
                is_straggler = True
                self.stragglers.append((step, seconds, deadline))
                if self.on_straggler is not None:
                    self.on_straggler(step, seconds, deadline)
        self.times.append(seconds)
        return is_straggler


def run_with_restarts(
    run: Callable[[int], int],
    max_restarts: int = 8,
) -> tuple[int, int]:
    """Drive ``run(start_attempt)`` until it completes, restarting on
    InjectedFailure — the supervisor loop a cluster manager provides.
    Returns (result, restarts_used)."""
    restarts = 0
    while True:
        try:
            return run(restarts), restarts
        except InjectedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
