"""Training data pipeline: deterministic synthetic corpus generation,
sequence packing, host-side prefetch, and per-data-shard dispatch.

Deterministic-by-step: batch(step) is a pure function of (seed, step), so a
restarted worker reproduces the exact stream — the property the fault-
tolerance layer relies on (no data loss / duplication across restarts).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from .tokenizer import HashTokenizer, synthetic_document


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    prefetch: int = 2
    zipf_alpha: float = 1.2    # realistic token frequency skew


class SyntheticLMStream:
    """Packs synthetic documents (BOS-delimited) into fixed-length rows."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.tok = HashTokenizer(cfg.vocab)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        rows = []
        for r in range(cfg.global_batch):
            toks: list[int] = []
            while len(toks) < cfg.seq_len + 1:
                doc = synthetic_document(rng, self.tok, alpha=cfg.zipf_alpha)
                toks.extend(doc)
            row = np.asarray(toks[: cfg.seq_len + 1], np.int32)
            rows.append(row)
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:].copy()}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchIterator:
    """Host-side background prefetch (overlaps data generation with compute)."""

    def __init__(self, stream: SyntheticLMStream, start_step: int = 0,
                 prefetch: int = 2):
        self.stream = stream
        self.q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.stream.batch_at(step)
            batch["step"] = step
            try:
                self.q.put(batch, timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __next__(self) -> dict:
        return self.q.get()

    def close(self):
        self._stop.set()


def shard_batch(batch: dict, mesh, batch_axes=("data",)) -> dict:
    """Place a host batch onto the mesh with the batch dim sharded."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    out = {}
    for k, v in batch.items():
        if k == "step":
            continue
        spec = P(batch_axes, *([None] * (np.ndim(v) - 1)))
        out[k] = jax.device_put(jnp.asarray(v), NamedSharding(mesh, spec))
    return out
