"""Control-plane checkpointing: the fleet controller's *learned* state as a
checkpoint tree.

The :mod:`repro.checkpoint` layer was built for training state (params /
opt_state pytrees); ROADMAP open item 3 asks the same machinery to cover
the *controller*, so a crashed fleet loop resumes warm instead of
re-learning its models and forecasts from scratch.  What actually needs to
survive a restart is small and precise:

* every tenant's :class:`~repro.control.learning.ModelStore` — node-model
  fit parameters, the calibration window behind the over-provisioning
  factor, and the monotonic ``version`` counter.  The version matters
  beyond bookkeeping: it is the invalidation token the engine's
  ResultCache and the scheduler's candidate-ladder memo key on, so a
  bit-for-bit restore keeps exactly the right cached results valid,
* every tenant's forecaster (Holt-Winters level/trend/seasonal state,
  replay history, EWMA level),
* the loop's guard memory (last acted-on target and breach flag per
  tenant), so the restarted controller holds/acts exactly where the dead
  one would have.

Everything is encoded as a nested dict whose leaves are numpy-compatible
scalars/arrays — the exact tree shape
:meth:`repro.checkpoint.Checkpointer.save` persists as one ``.npy`` per
leaf with an atomic manifest commit.  float64 leaves round-trip bit for
bit through ``np.save``/``np.load``, which is what the restore guarantees
lean on.

Deliberately NOT checkpointed: the deployed :class:`FleetPlan` and the
cluster's host lifecycle.  Placements are *derived* state — the recovered
controller senses the live cluster and replans deterministically — and
host health must be re-observed, never trusted from a file written before
the crash.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

if TYPE_CHECKING:
    from ..fleet.loop import FleetLoop
    from .checkpointer import Checkpointer


def controller_state(loop: "FleetLoop") -> dict:
    """The fleet loop's learned/guard state as a checkpoint tree."""
    tenants: dict = {}
    for spec in loop.tenants:
        name = spec.name
        if "/" in name:
            raise ValueError(
                f"tenant name {name!r} contains '/', which the checkpoint "
                "tree layout reserves as its key separator"
            )
        entry: dict = {
            "last_target": float(loop._last_target[name]),
            "breached": 1 if loop._breached[name] else 0,
        }
        state_dict = getattr(spec.models, "state_dict", None)
        if callable(state_dict):
            entry["models"] = state_dict()
        if spec.forecaster is not None and hasattr(
            spec.forecaster, "state_dict"
        ):
            entry["forecaster"] = spec.forecaster.state_dict()
        tenants[name] = entry
    return {"step": len(loop.events), "tenants": tenants}


def load_controller_state(loop: "FleetLoop", tree: dict) -> int:
    """Restore :func:`controller_state` into a freshly constructed loop.

    The loop must be built with the same tenant set (same names, same
    forecaster shapes) — structural state lives in code, the checkpoint
    carries only the learned values.  Returns the step count the saved
    controller had reached.  Tenants present in the loop but absent from
    the checkpoint are left cold (a tenant added after the save); saved
    tenants no longer in the loop are ignored (a tenant since retired).
    """
    tenants = tree.get("tenants", {})
    for spec in loop.tenants:
        entry = tenants.get(spec.name)
        if entry is None:
            continue
        loop._last_target[spec.name] = float(entry["last_target"])
        loop._breached[spec.name] = bool(int(entry["breached"]))
        if "models" in entry:
            load = getattr(spec.models, "load_state_dict", None)
            if not callable(load):
                raise ValueError(
                    f"checkpoint carries model state for tenant "
                    f"{spec.name!r} but its spec has no ModelStore"
                )
            load(entry["models"])
        if "forecaster" in entry:
            if spec.forecaster is None:
                raise ValueError(
                    f"checkpoint carries forecaster state for tenant "
                    f"{spec.name!r} but its spec has no forecaster"
                )
            spec.forecaster.load_state_dict(entry["forecaster"])
    return int(tree.get("step", 0))


def save_controller(
    ckpt: "Checkpointer", loop: "FleetLoop", blocking: bool = True
) -> int:
    """Persist the loop's control state at its current step (returns it)."""
    step = len(loop.events)
    ckpt.save(step, controller_state(loop), blocking=blocking)
    return step


def restore_controller(ckpt: "Checkpointer", loop: "FleetLoop") -> "int | None":
    """Load the newest valid checkpoint into ``loop`` (None: nothing saved).

    Returns the step count the saved controller had reached.  The restored
    loop has no deployed plan — its first ``step()`` replans from the live
    cluster — but its models, calibration, forecasters and guard memory
    are bit-for-bit the saved ones, so that replan is the one the dead
    controller would have produced."""
    latest = ckpt.restore_latest()
    if latest is None:
        return None
    _step, tree = latest
    return load_controller_state(loop, tree)
