"""h2o-danube-3-4b [dense]: 24L d=3840 32H (GQA kv=8) ff=10240 vocab=32000,
llama+mistral mix with sliding-window attention [arXiv:2401.16818]."""
from .base import ModelConfig, register, register_smoke


@register
def h2o_danube3_4b() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b", family="dense",
        n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
        d_ff=10240, vocab=32000, head_dim=120,
        sliding_window=4096, rope_theta=10_000.0,
        notes="SWA => windowed KV cache => long_500k supported",
    )


register_smoke("h2o-danube-3-4b", lambda: ModelConfig(
    name="h2o-danube-3-4b@smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    head_dim=16, sliding_window=32,
))
