"""Engine abstraction for configuration evaluation.

Every control layer (allocator candidate scoring, the Dhalion-style reactive
baseline, autoscaler calibration, benchmarks) asks the same question: *what
rate does this configuration achieve, and what limits it?*  This module
defines the :class:`ConfigEvaluator` protocol that answers it, plus two
backends:

* :class:`SimulatorEvaluator` — the discrete-time cluster simulator, with
  batched (vmapped) candidate sweeps and **sticky shape buckets**: once a
  bucket has been compiled, smaller configurations keep padding up to it, so
  a whole autoscaling trace re-uses one or two XLA compilations of the tick
  kernel.
* :class:`ExecutorEvaluator` — the real-JAX executor: operator bodies are
  timed on this host (:func:`repro.streams.executor.calibrate_dag`), and the
  calibrated costs feed the LP flow solver.  ``evaluate_batch`` is serial
  (real deployments cannot be vmapped), which is exactly why the protocol
  exists: control layers stay agnostic to how bulk evaluation happens.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Protocol, Sequence, runtime_checkable

import numpy as np

from ..core.dag import Configuration, DagSpec
from ..core.flow_solver import solve_flow
from ..core.metrics import STREAM_MANAGER
from ..core.node_model import oracle_models
from .cache import ResultCache
from .simulator import (
    SAMPLES_MODES,
    SimParams,
    SimResult,
    _grid_through_batch,
    batch_bucket_size,
    bucket_size,
    degree_bucket_size,
    edge_bucket_size,
    is_scalar_load,
    resolve_tick_kernel,
    simulate_batch,
    structure_for,
)

#: A multi-job evaluation request: one candidate-configuration list per job.
JobGroups = Sequence[Sequence[Configuration]]

#: Offered load far above any realistic capacity: backpressure gating
#: throttles the spouts and the achieved rate *is* the capacity.
OVERLOAD_KTPS = 1e6


class PerCandidateLoads(tuple):
    """A per-*candidate* offered-load entry for one ``evaluate_jobs`` group.

    A plain per-job load (scalar or per-sample trace) applies to every
    candidate of that job's group.  Wrapping a sequence of scalars in
    ``PerCandidateLoads`` instead gives each candidate its *own* offered
    load — the fleet scheduler uses this to score one forecast-window rate
    across a whole candidate set whose members sit on different-speed hosts
    (each candidate is driven at ``rate / its_min_host_speed`` in
    reference-host units), still inside one batched call.  The wrapper is
    the disambiguator: a bare sequence keeps meaning a shared per-sample
    trace."""

    __slots__ = ()


@dataclasses.dataclass(frozen=True)
class EvalResult:
    """One configuration's evaluation: achieved rate + limiting component."""

    config: Configuration
    achieved_ktps: float
    bottleneck: str | None            # node name, STREAM_MANAGER, or None
    sim: SimResult | None = None      # backend detail (simulator only)


@runtime_checkable
class ConfigEvaluator(Protocol):
    """What a configuration-evaluation backend must provide.

    All four entry points answer the same question at different shapes:
    *what rate does this configuration achieve under this offered load, and
    which component limits it?*  Control layers depend only on this
    protocol; how bulk evaluation happens (vmapped simulation, serial LP
    scoring of a real deployment, a caching wrapper...) is the backend's
    business.  Backends written before the multi-job/grid entry points
    existed keep working through :func:`evaluate_jobs_with` /
    :func:`evaluate_grid_with`.
    """

    def evaluate(
        self, config: Configuration, offered_ktps: float = OVERLOAD_KTPS
    ) -> EvalResult:
        """Score one configuration.

        Args:
            config: the physical configuration to score.
            offered_ktps: offered source load — a scalar rate or a
                per-sample trace.  The default :data:`OVERLOAD_KTPS` is far
                above any realistic capacity, so the achieved rate *is* the
                configuration's capacity (a capacity probe).

        Returns:
            An :class:`EvalResult` with the achieved rate and the limiting
            component (a node name, :data:`~repro.core.metrics
            .STREAM_MANAGER`, or None when unsaturated).
        """
        ...

    def evaluate_batch(
        self, configs: Sequence[Configuration], offered_ktps=OVERLOAD_KTPS
    ) -> list[EvalResult]:
        """Score N configurations in one call.

        Args:
            configs: the candidate configurations.
            offered_ktps: a shared scalar, or one load per *config* (each a
                scalar or per-sample trace).

        Returns:
            One :class:`EvalResult` per config, in input order.  Batching
            backends answer this with a single kernel dispatch; serial
            backends loop — callers must not assume either.
        """
        ...

    def evaluate_jobs(
        self, groups: JobGroups, offered_ktps=OVERLOAD_KTPS
    ) -> list[list[EvalResult]]:
        """Score candidate sets for N independent jobs in one call.

        Args:
            groups: ``groups[j]`` holds job ``j``'s candidate
                configurations — jobs may be entirely different DAGs.
            offered_ktps: a shared scalar, or one entry per *job*: a scalar
                or per-sample trace applied to every candidate of that
                job's group, or a :class:`PerCandidateLoads` giving each
                candidate its own load (the fleet scheduler's
                candidate-set shape).

        Returns:
            Per-job lists of :class:`EvalResult`, mirroring ``groups``'
            shape.  This is the fleet scheduler's joint-scoring primitive:
            every tenant's candidate set and forecast window costs one
            batched (device-sharded) evaluation.
        """
        ...

    def evaluate_grid(
        self, configs: Sequence[Configuration], rates_ktps
    ) -> list[list[EvalResult]]:
        """Score the configs × rates cross-product in one call.

        Args:
            configs: C candidate configurations.
            rates_ktps: R offered rates (scalars).

        Returns:
            ``out[i][j]`` scores config ``i`` at rate ``j``.  Predictive
            policies use this to check a candidate ladder against a whole
            forecast window; on batching backends the grid rides the
            vmapped batch axis in a single dispatch.
        """
        ...


def evaluate_grid_with(
    evaluator, configs: Sequence[Configuration], rates_ktps
) -> list["list[EvalResult]"]:
    """``evaluate_grid`` on *any* evaluator, including backends written
    before the grid entry point existed: those fall back to one flattened
    ``evaluate_batch`` over the cross-product — still a single batched call
    on batching backends.  Predictive policies call through this shim so
    old evaluators (counting/caching wrappers) keep working."""
    fn = getattr(evaluator, "evaluate_grid", None)
    if fn is not None:
        return fn(configs, rates_ktps)
    return _grid_through_batch(evaluator.evaluate_batch, configs, rates_ktps)


def _expand_job_loads(groups: list[list[Configuration]], offered_ktps):
    """Per-job offered loads → one per-config flat list.

    A scalar is shared by every config of every job; a per-job entry is a
    scalar or per-sample trace shared by that job's candidates, or a
    :class:`PerCandidateLoads` giving each candidate its own scalar load."""
    if is_scalar_load(offered_ktps):
        return [offered_ktps for g in groups for _ in g]
    loads = list(offered_ktps)
    if len(loads) != len(groups):
        raise ValueError(
            f"offered_ktps has {len(loads)} entries for {len(groups)} jobs"
        )
    flat = []
    for g, o in zip(groups, loads):
        if isinstance(o, PerCandidateLoads):
            if len(o) != len(g):
                raise ValueError(
                    f"PerCandidateLoads has {len(o)} entries for a "
                    f"{len(g)}-candidate group"
                )
            flat.extend(float(x) for x in o)
        else:
            flat.extend(o for _ in g)
    return flat


def _regroup(flat: list, groups: list[list]) -> list[list]:
    """Undo the flattening: slice per-config results back into job groups."""
    out: list[list] = []
    i = 0
    for g in groups:
        out.append(flat[i : i + len(g)])
        i += len(g)
    return out


def evaluate_jobs_with(
    evaluator, groups: JobGroups, offered_ktps=OVERLOAD_KTPS
) -> list["list[EvalResult]"]:
    """``evaluate_jobs`` on *any* evaluator, including backends written
    against the pre-multi-job protocol (``evaluate``/``evaluate_batch``
    only, e.g. counting/caching wrappers): those fall back to one flattened
    ``evaluate_batch`` call with the same grouping semantics.  The fleet
    layer calls through this shim so old evaluators keep working."""
    fn = getattr(evaluator, "evaluate_jobs", None)
    if fn is not None:
        return fn(groups, offered_ktps)
    groups = [list(g) for g in groups]
    flat = [c for g in groups for c in g]
    if not flat:
        return [[] for _ in groups]
    loads = _expand_job_loads(groups, offered_ktps)
    return _regroup(evaluator.evaluate_batch(flat, loads), groups)


class SimulatorEvaluator:
    """Batched simulator backend with sticky shape buckets.

    ``duration_s`` trades fidelity for speed (8 s reaches steady state for
    the bundled workloads).  With ``sticky_buckets`` every call pads at least
    to the largest bucket seen so far, so bucket growth — not call count —
    determines the number of XLA compilations.  ``devices`` is forwarded to
    :func:`~repro.streams.simulator.simulate_batch`: ``None`` (auto) shards
    large batches across every local device, ``1`` pins single-device vmap.

    ``sticky_batch`` extends the same idea to the *batch axis*: batch sizes
    pad up to a sticky :data:`~repro.streams.simulator.BATCH_LADDER` rung
    (replicating the last configuration; replicas are dropped on unpack), so
    a fleet trace whose per-replan candidate count fluctuates keeps hitting
    one compiled kernel and a stable device-shard count.  Off by default:
    for one-shot batches the padding is pure overhead.

    ``tick_kernel`` picks the flow-physics backend (``"dense"``,
    ``"sparse"``, or ``"auto"``).  ``"auto"`` is resolved ONCE, from the
    first batch seen, and then pinned — a per-call decision could flip the
    backend as candidate sets fluctuate and recompile.  The sparse edge
    bucket is sticky like the shape buckets.  ``resident_batches`` turns on
    the device-resident staging cache in :func:`simulate_batch` — the fleet
    scheduler re-scores largely identical candidate sets every replan, so
    repeated submissions skip ``np.stack`` + host→device transfer (results
    stay bitwise identical).  ``saturation_threshold`` is forwarded to
    :meth:`SimResult.bottleneck_node` when labelling the limiting component.

    ``dedup`` / ``cache`` turn on the cache-first evaluation path
    (:func:`~repro.streams.simulator.simulate_batch` Tiers 1 and 2):
    value-identical rows in one batch collapse to one kernel row, and
    unique rows are memoized across calls in a per-evaluator
    :class:`~repro.streams.cache.ResultCache` (``cache=True`` builds one;
    pass an instance to share it, ``False`` to disable).  Both tiers are
    bitwise-transparent — ``SimulatorEvaluator(dedup=False, cache=False)``
    is the escape hatch reproducing the uncached path exactly.
    ``version_source`` is the invalidation hook: any object exposing a
    ``version`` attribute (a :class:`~repro.control.learning.ModelStore`,
    or the fleet loop's aggregate clock) is folded into every cache key,
    so calibration/retrain bumps make stale entries unreachable.  The
    control/fleet loops wire it automatically when left unset.

    ``samples`` picks the per-result payload forwarded to
    :func:`~repro.streams.simulator.simulate_batch`.  The default
    ``"summary"`` keeps trajectories on device — every scoring consumer of
    an :class:`EvalResult` (``achieved_ktps`` + ``bottleneck``) is answered
    from the on-device reductions, with values exactly equal to full mode —
    and the rare trajectory consumer (a control loop pooling
    ``sim.to_metrics_store()`` on saturation) transparently refetches.
    ``samples="full"`` restores the historical O(B·S·I) transfers.
    """

    def __init__(
        self,
        params: SimParams = SimParams(),
        duration_s: float = 8.0,
        sticky_buckets: bool = True,
        devices: int | None = None,
        sticky_batch: bool = False,
        tick_kernel: str = "auto",
        resident_batches: bool = True,
        saturation_threshold: float = 0.8,
        dedup: bool = True,
        cache: "bool | ResultCache" = True,
        version_source=None,
        samples: str = "summary",
    ) -> None:
        if samples not in SAMPLES_MODES:
            raise ValueError(f"samples={samples!r} not in {SAMPLES_MODES}")
        self.samples = samples
        self.params = params
        self.duration_s = duration_s
        self.sticky_buckets = sticky_buckets
        self.devices = devices
        self.sticky_batch = sticky_batch
        self.tick_kernel = tick_kernel
        self.resident_batches = resident_batches
        self.saturation_threshold = saturation_threshold
        self.dedup = dedup
        if cache is True:
            cache = ResultCache(name="simulator")
        # identity test, not truthiness: an *empty* ResultCache is len() 0
        self.result_cache: ResultCache | None = (
            cache if isinstance(cache, ResultCache) else None
        )
        self.version_source = version_source
        self._inst_floor = 0
        self._cont_floor = 0
        self._batch_floor = 0
        self._edge_floor = 0
        self._degree_floor = 0
        self._backend: str | None = None if tick_kernel == "auto" else tick_kernel
        # shape-scan memo: flat config tuple (by identity) -> bucket inputs;
        # the fleet scheduler re-submits largely identical candidate lists
        # every replan, so the O(total instances) packing scan runs once per
        # distinct layout.  Values hold the configs, keeping the ids valid.
        self._layout_memo: OrderedDict[tuple, tuple] = OrderedDict()

    def presize(
        self, n_inst: int, n_cont: int, n_batch: int = 0, n_edges: int = 0,
        max_degree: int = 0,
    ) -> None:
        """Pin bucket floors for the largest configuration (and optionally
        batch size / sparse edge count / ELL row width) expected —
        guarantees a single compilation up front."""
        self._inst_floor = max(self._inst_floor, bucket_size(n_inst))
        self._cont_floor = max(self._cont_floor, bucket_size(n_cont))
        if n_batch:
            self._batch_floor = max(self._batch_floor, batch_bucket_size(n_batch))
        if n_edges:
            self._edge_floor = max(self._edge_floor, edge_bucket_size(n_edges))
        if max_degree:
            self._degree_floor = max(
                self._degree_floor, degree_bucket_size(max_degree)
            )

    def _layout(self, configs: list[Configuration]) -> tuple[int, int, int, int]:
        """Max (instances, containers, edges, in-/out-degree) across
        ``configs`` — memoized on the identity signature of the batch so
        repeated submissions of the same candidate layout (fleet replans)
        skip the packing re-scan."""
        sig = tuple(id(c) for c in configs)
        hit = self._layout_memo.get(sig)
        if hit is not None:
            self._layout_memo.move_to_end(sig)
            return hit[1], hit[2], hit[3], hit[4]
        n_inst = max(sum(len(p) for p in c.packing) for c in configs)
        n_cont = max(c.n_containers for c in configs)
        # structure_for is value-memoized, so this warms the same cache
        # simulate_batch reads — no duplicate structure builds
        sts = [structure_for(c, self.params) for c in configs]
        n_edges = max(st.n_edges for st in sts)
        d_max = max(max(st.d_out, st.d_in) for st in sts)
        self._layout_memo[sig] = (tuple(configs), n_inst, n_cont, n_edges, d_max)
        if len(self._layout_memo) > 128:
            self._layout_memo.popitem(last=False)
        return n_inst, n_cont, n_edges, d_max

    def _cache_token(self):
        """Invalidation token folded into every result-cache key: the
        ``version`` of :attr:`version_source` (``None`` when unwired —
        cached entries then live until LRU eviction)."""
        vs = self.version_source
        if vs is None:
            return None
        return ("models", getattr(vs, "version", None))

    def evaluate(
        self, config: Configuration, offered_ktps: float = OVERLOAD_KTPS
    ) -> EvalResult:
        return self.evaluate_batch([config], offered_ktps)[0]

    def evaluate_batch(
        self, configs: Sequence[Configuration], offered_ktps=OVERLOAD_KTPS
    ) -> list[EvalResult]:
        configs = list(configs)
        if not configs:
            return []
        if self.sticky_buckets:
            n_inst, n_cont, n_edges, d_max = self._layout(configs)
            self._inst_floor = max(self._inst_floor, bucket_size(n_inst))
            self._cont_floor = max(self._cont_floor, bucket_size(n_cont))
            if self._backend is None:
                # pin "auto" on first contact so later batches with different
                # densities never flip the backend (and recompile)
                self._backend = resolve_tick_kernel(n_inst, n_edges, "auto")
            if self._backend == "sparse":
                self._edge_floor = max(
                    self._edge_floor, edge_bucket_size(n_edges)
                )
                self._degree_floor = max(
                    self._degree_floor, degree_bucket_size(d_max)
                )
        if self.sticky_batch:
            self._batch_floor = max(
                self._batch_floor, batch_bucket_size(len(configs))
            )
        results = simulate_batch(
            configs,
            offered_ktps,
            duration_s=self.duration_s,
            params=self.params,
            min_inst_bucket=self._inst_floor,
            min_cont_bucket=self._cont_floor,
            devices=self.devices,
            min_batch_bucket=self._batch_floor,
            tick_kernel=self._backend if self._backend else self.tick_kernel,
            min_edge_bucket=self._edge_floor,
            min_degree_bucket=self._degree_floor,
            resident=self.resident_batches,
            samples=self.samples,
            dedup=self.dedup,
            cache=self.result_cache,
            cache_token=self._cache_token(),
        )
        return [
            EvalResult(
                config=c,
                achieved_ktps=r.achieved_ktps,
                bottleneck=r.bottleneck_node(self.saturation_threshold),
                sim=r,
            )
            for c, r in zip(configs, results)
        ]

    def evaluate_jobs(
        self, groups: JobGroups, offered_ktps=OVERLOAD_KTPS
    ) -> list[list[EvalResult]]:
        """Score candidate sets for N independent jobs in ONE sharded kernel
        call.

        ``groups[j]`` holds job ``j``'s candidate configurations (the jobs
        may be entirely different DAGs — padding buckets them together);
        ``offered_ktps`` is a shared scalar or one load per *job* (scalar or
        per-sample trace, applied to every candidate of that job).  This is
        the fleet scheduler's joint-scoring primitive: all tenants' candidate
        allocations cost one batched (device-sharded) evaluation.
        """
        groups = [list(g) for g in groups]
        flat = [c for g in groups for c in g]
        if not flat:
            return [[] for _ in groups]
        loads = _expand_job_loads(groups, offered_ktps)
        return _regroup(self.evaluate_batch(flat, loads), groups)

    def evaluate_grid(
        self, configs: Sequence[Configuration], rates_ktps
    ) -> list[list[EvalResult]]:
        """Candidate-configs × horizon-rates in ONE vmapped kernel call —
        the rates ride the batch axis (config-major cross-product), so a
        predictive policy's whole window sweep reuses the sticky shape
        buckets and costs no extra compilations beyond its batch shape."""
        return _grid_through_batch(self.evaluate_batch, configs, rates_ktps)


class ExecutorEvaluator:
    """Real-JAX executor backend.

    Operator bodies are run and timed once per DAG (cached); a configuration
    is then scored by the LP flow solver under the calibrated per-node costs.
    The bottleneck is the most-saturated component at the solved rates,
    mirroring :meth:`SimResult.bottleneck_node` semantics.

    ``cache`` memoizes whole :class:`EvalResult`\\ s by value across calls
    (Tier 2 of the cache-first path, same contract as
    :class:`SimulatorEvaluator`): the key is the calibration identity
    (DagSpec value + operator-body ids), the configuration, the offered
    load, the scoring thresholds, and the ``version_source`` token — so a
    fleet step that re-scores an unchanged candidate set skips the LP
    entirely, and any model/calibration version bump invalidates.

    ``samples`` is accepted for constructor symmetry with
    :class:`SimulatorEvaluator` (callers swap backends without branching);
    the LP scoring path has no trajectories to ship, so every result is
    already summary-shaped and the value only validates.
    """

    def __init__(
        self,
        n_batches: int = 5,
        floor_ktps: float = 50.0,
        sm_cost_per_ktuple: float = SimParams.sm_cost_per_ktuple,
        saturation_threshold: float = 0.8,
        cache: "bool | ResultCache" = True,
        version_source=None,
        samples: str = "summary",
    ) -> None:
        if samples not in SAMPLES_MODES:
            raise ValueError(f"samples={samples!r} not in {SAMPLES_MODES}")
        self.samples = samples
        self.n_batches = n_batches
        self.floor_ktps = floor_ktps
        self.sm_cost_per_ktuple = sm_cost_per_ktuple
        self.saturation_threshold = saturation_threshold
        if cache is True:
            # EvalResults are tiny (no sim payload): bound by entries
            cache = ResultCache(
                name="executor", max_entries=65536, max_bytes=1 << 24
            )
        self.result_cache: ResultCache | None = (
            cache if isinstance(cache, ResultCache) else None
        )
        self.version_source = version_source
        # keyed by the DagSpec *value* plus its operator-body identities:
        # DagSpec equality excludes NodeSpec.fn (compare=False), but fn is
        # exactly what this backend times — two DAGs with identical declared
        # specs and different real operators must not alias each other's
        # measured costs (nor may a spec and its recalibrated namesake)
        self._calibrated: dict[tuple, DagSpec] = {}
        # identity signatures of DAG batches already validated+calibrated:
        # repeated ``evaluate_jobs``/``evaluate_batch`` calls over an
        # unchanged group layout (every fleet step) skip the per-config
        # ``_cache_key`` hashing sweep.  Values hold the dags so the ids in
        # the key stay valid.
        self._groups_seen: OrderedDict[tuple, tuple] = OrderedDict()

    def _precalibrate_once(self, dags: Sequence[DagSpec]) -> None:
        sig = tuple(id(d) for d in dags)
        if sig in self._groups_seen:
            self._groups_seen.move_to_end(sig)
            return
        self.precalibrate(dags)
        self._groups_seen[sig] = tuple(dags)
        if len(self._groups_seen) > 128:
            self._groups_seen.popitem(last=False)

    @staticmethod
    def _cache_key(dag: DagSpec) -> tuple:
        # id() of each fn is stable while the dag (kept alive in the cache
        # key) holds a reference to it
        return (dag, tuple(id(n.fn) for n in dag.nodes))

    def _dag_for(self, dag: DagSpec) -> DagSpec:
        key = self._cache_key(dag)
        cal = self._calibrated.get(key)
        if cal is None:
            from .executor import calibrate_dag

            cal = calibrate_dag(
                dag, n_batches=self.n_batches, floor_ktps=self.floor_ktps
            )
            self._calibrated[key] = cal
        return cal

    def precalibrate(self, dags: Sequence[DagSpec]) -> None:
        """Time each *distinct* DAG's operator bodies exactly once — called
        up front by the batch entry points so a batch over N configurations
        of k DAGs costs k timing runs, not N."""
        for dag in dags:
            self._dag_for(dag)

    def calibrated_dag(self, dag: DagSpec) -> DagSpec:
        """The DAG with this host's measured per-ktuple costs (cached) —
        consumed by :func:`repro.control.learning.fold_executor_timings` to
        re-parameterize the simulator's physical truth."""
        return self._dag_for(dag)

    def _eval_key(self, config: Configuration, offered: float):
        token = None
        if self.version_source is not None:
            token = getattr(self.version_source, "version", None)
        return (
            self._cache_key(config.dag), config, float(offered),
            self.saturation_threshold, self.sm_cost_per_ktuple, token,
        )

    def evaluate(
        self, config: Configuration, offered_ktps: float = OVERLOAD_KTPS
    ) -> EvalResult:
        key = None
        if self.result_cache is not None and is_scalar_load(offered_ktps):
            key = self._eval_key(config, float(offered_ktps))
            hit = self.result_cache.get(key)
            if hit is not None:
                return hit
        result = self._evaluate_uncached(config, offered_ktps)
        if key is not None:
            # frozen EvalResult without a sim payload: nominal footprint
            self.result_cache.put(key, result, nbytes=128)
        return result

    def _evaluate_uncached(
        self, config: Configuration, offered_ktps: float
    ) -> EvalResult:
        dag2 = self._dag_for(config.dag)
        cfg2 = Configuration(dag2, config.packing, config.dims)
        models = oracle_models(dag2, self.sm_cost_per_ktuple)
        sol = solve_flow(cfg2, models)
        if not sol.feasible:
            return EvalResult(config=config, achieved_ktps=0.0, bottleneck=None)
        achieved = min(float(sol.rate_ktps), float(offered_ktps))
        # saturation per node at the solved instance rates
        per_node: dict[str, float] = {}
        for (nm, _c, _s), rate in sol.instance_rates.items():
            util = rate * models[nm].cap.slope
            per_node[nm] = max(per_node.get(nm, 0.0), util)
        sm_util = max(
            (t * self.sm_cost_per_ktuple for t in sol.sm_traversals.values()),
            default=0.0,
        )
        bottleneck: str | None = None
        if per_node:
            name, val = max(per_node.items(), key=lambda kv: kv[1])
            if sm_util > val and sm_util > 0.9:
                bottleneck = STREAM_MANAGER
            elif val > self.saturation_threshold:
                bottleneck = name
        return EvalResult(config=config, achieved_ktps=achieved, bottleneck=bottleneck)

    def evaluate_batch(
        self, configs: Sequence[Configuration], offered_ktps=OVERLOAD_KTPS
    ) -> list[EvalResult]:
        if is_scalar_load(offered_ktps):
            offered = [float(offered_ktps)] * len(configs)
        else:
            offered = [float(np.max(o)) for o in offered_ktps]
            if len(offered) != len(configs):
                raise ValueError(
                    f"offered_ktps has {len(offered)} entries for "
                    f"{len(configs)} configs"
                )
        self._precalibrate_once([c.dag for c in configs])
        return [self.evaluate(c, o) for c, o in zip(configs, offered)]

    def evaluate_jobs(
        self, groups: JobGroups, offered_ktps=OVERLOAD_KTPS
    ) -> list[list[EvalResult]]:
        """Multi-job scoring on the real-executor backend: every distinct
        DAG across all jobs is timed once, then candidates score serially
        through the calibrated LP flow solver."""
        groups = [list(g) for g in groups]
        loads = _expand_job_loads(groups, offered_ktps)
        self._precalibrate_once([c.dag for g in groups for c in g])
        # the flow solver answers a single-rate question: a per-sample trace
        # reduces to its peak (the capacity the job must sustain)
        flat = [
            self.evaluate(c, float(np.max(o)))
            for c, o in zip((c for g in groups for c in g), loads)
        ]
        return _regroup(flat, groups)

    def evaluate_grid(
        self, configs: Sequence[Configuration], rates_ktps
    ) -> list[list[EvalResult]]:
        """Grid scoring on the real-executor backend: each distinct DAG is
        timed once, then the (config, rate) pairs score serially through
        the calibrated LP flow solver."""

        def batch(flat_cfgs, flat_loads):
            self.precalibrate([c.dag for c in flat_cfgs])
            return [self.evaluate(c, o) for c, o in zip(flat_cfgs, flat_loads)]

        return _grid_through_batch(batch, configs, rates_ktps)
