"""seamless-m4t-large-v2 [audio]: 24L enc + 24L dec, d=1024 16H ff=8192
vocab=256206, multimodal enc-dec; audio frontend STUB (precomputed frame
embeddings) [arXiv:2308.11596]."""
from .base import ModelConfig, register, register_smoke


@register
def seamless_m4t_large_v2() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="audio",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab=256206, head_dim=64,
        enc_layers=24, frontend="audio", frontend_tokens=512,
        notes="enc-dec: decode shapes exercise the decoder w/ cross-attn cache",
    )


register_smoke("seamless-m4t-large-v2", lambda: ModelConfig(
    name="seamless-m4t-large-v2@smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
    head_dim=16, enc_layers=2, frontend="audio", frontend_tokens=16,
))
