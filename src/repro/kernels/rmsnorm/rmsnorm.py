"""Fused RMSNorm Pallas TPU kernel.

Row-tiled: each program normalizes a (block_rows × d) tile entirely in VMEM —
one HBM read + one write per element instead of the unfused read(x), write(sq),
read(sq)... chain.  fp32 accumulation, cast back to the input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    ms = (x * x).mean(axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * g).astype(o_ref.dtype)


def rmsnorm_pallas(x: jax.Array, gain: jax.Array, eps: float = 1e-5,
                   block_rows: int = 256, interpret: bool = False) -> jax.Array:
    orig_shape = x.shape
    d = x.shape[-1]
    rows = x.size // d
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // block_rows,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, gain)
    return out[:rows].reshape(orig_shape)
